"""Jit'd public wrapper for MXU triangle counting.

``triangle_count_dense(csr | dense)``: renders (a cohort of) an adjacency
into a padded 0/1 float32 matrix and counts triangles on the MXU. For
symmetric adjacencies the raw sum is 6x the triangle count; for pruned DAGs
(src > dst) it is exact. The caller states which via ``symmetric=``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.common import interpret_default, round_up
from repro.kernels.triangle_mm.kernel import triangle_mm_kernel

_BLOCK = 256


def triangle_count_dense(a, *, symmetric: bool, interpret=None,
                         block: int = _BLOCK):
    """Triangle count of a dense 0/1 adjacency matrix [n, n]."""
    if interpret is None:
        interpret = interpret_default()
    a = jnp.asarray(a, jnp.float32)
    n = a.shape[0]
    npad = round_up(max(n, block), block)
    if npad != n:
        a = jnp.zeros((npad, npad), jnp.float32).at[:n, :n].set(a)
    raw = triangle_mm_kernel(a, block=block, interpret=interpret)[0, 0]
    return raw / 6.0 if symmetric else raw


def densify_csr(offsets, neighbors, n: int) -> np.ndarray:
    """CSR -> dense 0/1 float32 (host-side; used for the dense cohort)."""
    out = np.zeros((n, n), dtype=np.float32)
    src = np.repeat(np.arange(n), np.diff(offsets))
    out[src, neighbors] = 1.0
    return out
