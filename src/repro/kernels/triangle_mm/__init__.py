from repro.kernels.triangle_mm.ops import triangle_count_dense  # noqa: F401
