"""Pallas TPU kernel: MXU masked-matmul triangle counting.

Beyond-paper optimization (DESIGN.md §2): for the *dense cohort* of the
set-layout optimizer, triangle counting over a 0/1 adjacency block is

    count = sum( (A @ A) * A )

which maps onto the 128x128 systolic MXU instead of the VPU — the CPU paper
has no analogue of this formulation (AVX has no systolic unit). On pruned
DAGs (src > dst, the paper's symmetric filtering) the sum counts each
triangle exactly once; on symmetric adjacencies it counts 6x.

Grid (i, j, k): C_ij partial accumulates over k in a VMEM scratch; on the
last k step the partial is masked by A_ij and folded into a scalar output.

  a   : [n, n] float32 0/1 adjacency (padded to 128 multiples)
  out : [1, 1] float32 triangle count (before symmetry division)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv


def _kernel(a_ik_ref, a_kj_ref, a_ij_ref, out_ref, acc_ref, *, n_k: int):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((i == 0) & (j == 0) & (k == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU contraction for this (i, j) tile's k-slice.
    acc_ref[...] += jnp.dot(a_ik_ref[...], a_kj_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _fold():
        masked = acc_ref[...] * a_ij_ref[...]
        out_ref[0, 0] += masked.sum()


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def triangle_mm_kernel(a, *, block: int = 256, interpret: bool = False):
    n = a.shape[0]
    assert a.shape == (n, n) and n % block == 0, a.shape
    nb = cdiv(n, block)
    kernel = functools.partial(_kernel, n_k=nb)
    return pl.pallas_call(
        kernel,
        grid=(nb, nb, nb),
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, k: (i, k)),  # A_ik
            pl.BlockSpec((block, block), lambda i, j, k: (k, j)),  # A_kj
            pl.BlockSpec((block, block), lambda i, j, k: (i, j)),  # A_ij mask
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block, block), jnp.float32)],
        interpret=interpret,
    )(a, a, a)
