"""Jit'd public wrapper for the materializing bitset-intersection kernel.

``bitset_pair_materialize(bs, a_slots, b_slots)`` is the device twin of
:func:`repro.core.intersect.bitset_intersect_materialize`: same contract
(``(pair_id, values, rank_a, rank_b)``, pair-major, values ascending),
but the AND + rank arithmetic runs on device in ONE fused jitted call —
block-row gather, uint32→bit expansion, Pallas AND + triangular-matmul
ranks — and the ragged extraction is a device count-then-fill
(``_extract_pairs``): set bits scatter to a dense prefix sized by the
exact ``p * block_bits`` bound, so the single closing ``host_get``
carries already-compacted positions and ranks.  The host ``np.nonzero``
pass (and its full-plane transfer) is gone.
"""
from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import host_get, interpret_default, round_up
from repro.kernels.materialize.kernel import bitset_materialize_kernel

_BLOCK_ROWS = 256

# strictly-upper-triangular ones (tri[s, t] = 1 iff s < t) per block_bits
_TRI_CACHE: Dict[int, jnp.ndarray] = {}


def _tri(block_bits: int) -> jnp.ndarray:
    t = _TRI_CACHE.get(block_bits)
    if t is None:
        t = jnp.asarray(np.triu(np.ones((block_bits, block_bits),
                                        np.float32), 1))
        _TRI_CACHE[block_bits] = t
    return t


@partial(jax.jit, static_argnames=("block_bits", "interpret"))
def _gather_expand_rank(words, pos_a, pos_b, tri, *, block_bits: int,
                        interpret: bool):
    """Gather matched block rows, expand words to bit planes, run the
    Pallas kernel. One device program — callers sync exactly once."""
    p = pos_a.shape[0]
    wpb = words.shape[1]
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, wpb, 32), 2)

    def expand(pos):
        w = words[pos]                                   # [P, wpb] uint32
        bits = (w[:, :, None] >> shifts) & jnp.uint32(1)
        return bits.reshape(p, wpb * 32).astype(jnp.int32)

    ppad = round_up(max(p, _BLOCK_ROWS), _BLOCK_ROWS)
    ba = jnp.zeros((ppad, block_bits), jnp.int32).at[:p].set(expand(pos_a))
    bb = jnp.zeros((ppad, block_bits), jnp.int32).at[:p].set(expand(pos_b))
    band, ra, rb = bitset_materialize_kernel(
        ba, bb, tri, block_rows=_BLOCK_ROWS, interpret=interpret)
    return band[:p], ra[:p], rb[:p]


def _device_words(bs) -> jnp.ndarray:
    """Device-resident copy of the cohort's bitvector blocks, uploaded
    once and cached on the BlockedBitset (identity-keyed, the
    ``TrieLevel.device_values`` idiom)."""
    cached = bs.__dict__.get("_dev_words")
    if cached is None or cached[0] is not bs.words:
        cached = (bs.words, jnp.asarray(bs.words))
        bs._dev_words = cached
    return cached[1]


def bitset_pair_materialize(bs, a_slots, b_slots, *, interpret=None):
    """Materializing dense-cohort intersection via the Pallas kernel.

    ``bs`` is a :class:`repro.core.intersect.BlockedBitset`; slots index
    its cohort. Matches :func:`~repro.core.intersect.
    bitset_intersect_materialize` bit-for-bit (same values, same ranks,
    same order).
    """
    from repro.core.intersect import intersect_pairs_uint  # avoid cycle
    if interpret is None:
        interpret = interpret_default()
    a_slots = np.asarray(a_slots, np.int64)
    b_slots = np.asarray(b_slots, np.int64)
    pair_id, _blk, pos_a, pos_b = intersect_pairs_uint(
        bs.offsets, bs.block_ids, a_slots, b_slots)
    z = np.zeros(0, np.int64)
    if len(pair_id) == 0:
        return z, np.zeros(0, np.int32), z, z
    band, ra, rb = _gather_expand_rank(
        _device_words(bs), jnp.asarray(pos_a), jnp.asarray(pos_b),
        _tri(bs.block_bits), block_bits=bs.block_bits,
        interpret=bool(interpret))
    # device count-then-fill extraction (the exact p*block_bits bound
    # sizes the scatter, so it cannot overflow), then the ONE host
    # round-trip of already-compacted positions and ranks
    total, pos_c, ra_c, rb_c = _extract_pairs(band, ra, rb)
    total, pos_h, ra_h, rb_h = host_get((total, pos_c, ra_c, rb_c))
    n = int(total)
    pos_h = np.asarray(pos_h)[:n].astype(np.int64)
    blk_row = pos_h // bs.block_bits
    bitpos = pos_h % bs.block_bits
    vals = (bs.block_ids[pos_a[blk_row]].astype(np.int64) * bs.block_bits
            + bitpos)
    rank_a = bs.index[pos_a[blk_row]] + np.asarray(ra_h)[:n]
    rank_b = bs.index[pos_b[blk_row]] + np.asarray(rb_h)[:n]
    return (pair_id[blk_row], vals.astype(np.int32),
            rank_a.astype(np.int64), rank_b.astype(np.int64))


@jax.jit
def _extract_pairs(band, ra, rb):
    """Compact the AND-ed bit plane's set bits to a dense prefix on
    device: flatten row-major (so (block-row, bit) order — hence pair-
    major, values-ascending — survives), exclusive-scan the mask into
    scatter targets, and gather each match's flat position and both
    ranks.  Replaces the host ``np.nonzero`` ragged extraction."""
    cap = band.size
    flat = band.reshape(-1) > 0
    widx = jnp.cumsum(flat.astype(jnp.int32)) - 1
    total = widx[-1] + 1
    scat = jnp.where(flat, widx, cap)
    j = jnp.arange(cap, dtype=jnp.int32)

    def compact(x):
        return jnp.zeros((cap,), x.dtype).at[scat].set(x, mode="drop")

    return (total, compact(j), compact(ra.reshape(-1)),
            compact(rb.reshape(-1)))


def _contract_inputs():
    rng = np.random.default_rng(0)
    p, b = _BLOCK_ROWS, 128   # one full tile: the raw kernel's minimum
    ba = rng.integers(0, 2, size=(p, b)).astype(np.int32)
    bb = rng.integers(0, 2, size=(p, b)).astype(np.int32)
    return ba, bb


def _contract_entry(ba, bb):
    return bitset_materialize_kernel(
        jnp.asarray(ba), jnp.asarray(bb), _tri(ba.shape[1]),
        block_rows=_BLOCK_ROWS, interpret=True)


def _contract_ref(ba, bb):
    from repro.kernels.materialize.ref import bitset_materialize_ref
    return bitset_materialize_ref(jnp.asarray(ba), jnp.asarray(bb))


# Static contract (see repro.analysis.kernel_check.check_contract): the
# raw kernel wrapper (the ragged host extraction above it needs a live
# BlockedBitset) against the pure-jnp band/rank oracle.
CONTRACT = {
    "name": "materialize",
    "entry": _contract_entry,
    "ref": _contract_ref,
    "make_inputs": _contract_inputs,
}


def as_materialize_kernel(interpret=None):
    """Adapter matching HybridSetStore's ``materialize_kernel`` callable
    (``(bs, a_slots, b_slots) -> (pair_id, values, rank_a, rank_b)``)."""
    def fn(bs, a_slots, b_slots):
        return bitset_pair_materialize(bs, a_slots, b_slots,
                                       interpret=interpret)
    return fn
