"""Pure-jnp oracle for the materializing bitset-intersection kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def bitset_materialize_ref(bits_a, bits_b):
    """(band, rank_a, rank_b): AND-ed plane + per-endpoint exclusive
    prefix popcounts along the bit axis."""
    band = bits_a & bits_b
    ra = jnp.cumsum(bits_a, axis=1) - bits_a
    rb = jnp.cumsum(bits_b, axis=1) - bits_b
    return band, ra.astype(jnp.int32), rb.astype(jnp.int32)
