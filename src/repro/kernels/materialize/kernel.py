"""Pallas TPU kernel: materializing bitset intersection (paper Section
4.2 / Figure 6, the MATERIALIZE counterpart of ``bitset_intersect``).

``HybridSetStore.intersect_materialize`` needs, for every matched block
pair, (a) the AND-ed bit plane (which elements survive) and (b) each
element's RANK within BOTH endpoint sets — the paper's Figure-6 ``index``
machinery ("used to address associated values / next-trie-level
pointers").  The seed computed all of it on host: ``np.unpackbits`` over
the AND-ed words plus two full popcount+cumsum passes per endpoint.

This kernel moves the arithmetic onto the device.  Inputs are the
*bit-expanded* planes of the matched block rows (the uint32→bit unpack is
a cheap XLA shift-and-mask in ops.py, so kernel operands stay lane-
aligned: ``block_bits`` is a multiple of 128):

  bits_a, bits_b : [P, B] int32 0/1   (B = block_bits)
  tri            : [B, B] float32     strictly-upper-triangular ones
                                      (tri[s, t] = 1 iff s < t)

and one grid step emits, per (block_rows, B) tile:

  band   = bits_a & bits_b                     (VPU AND)
  rank_x = (bits_x . tri)                      (MXU matmul)

The triangular matmul IS the exclusive prefix-popcount: rank_x[p, t] =
number of set bits of endpoint x strictly below bit t — the classic
TPU prefix-scan-as-matmul trick, one 128x128 systolic pass instead of a
33-step word/bit cumsum.  The host keeps only the ragged extraction
(``np.nonzero`` of the returned plane).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import LANE, SUBLANE, cdiv


def _kernel(ba_ref, bb_ref, tri_ref, and_ref, ra_ref, rb_ref):
    """One grid step: AND the bit planes, matmul both against ``tri``."""
    ba = ba_ref[...]
    bb = bb_ref[...]
    and_ref[...] = ba & bb
    tri = tri_ref[...]
    ra = jnp.dot(ba.astype(jnp.float32), tri,
                 preferred_element_type=jnp.float32)
    rb = jnp.dot(bb.astype(jnp.float32), tri,
                 preferred_element_type=jnp.float32)
    ra_ref[...] = ra.astype(jnp.int32)
    rb_ref[...] = rb.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def bitset_materialize_kernel(bits_a, bits_b, tri, *, block_rows: int = 256,
                              interpret: bool = False):
    """``pallas_call`` wrapper; P padded to block_rows, B a LANE multiple."""
    p, b = bits_a.shape
    assert bits_b.shape == (p, b) and tri.shape == (b, b)
    assert p % block_rows == 0 and b % LANE == 0, (p, b)
    assert block_rows % SUBLANE == 0
    grid = (cdiv(p, block_rows),)
    spec = pl.BlockSpec((block_rows, b), lambda i: (i, 0))
    tri_spec = pl.BlockSpec((b, b), lambda i: (0, 0))
    out_shape = jax.ShapeDtypeStruct((p, b), jnp.int32)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec, tri_spec],
        out_specs=(spec, spec, spec),
        out_shape=(out_shape, out_shape, out_shape),
        interpret=interpret,
    )(bits_a, bits_b, tri)
