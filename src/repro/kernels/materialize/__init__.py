from repro.kernels.materialize.ops import bitset_pair_materialize  # noqa: F401
