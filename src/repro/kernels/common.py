"""Shared Pallas kernel utilities.

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling). This container
is CPU-only, so ``interpret_default()`` flips every kernel into interpret
mode, which executes the kernel body in Python for correctness validation
against the pure-jnp oracles in each kernel's ``ref.py``.

TPU tiling notes (v5e): int32/float32 native VREG tile is (8, 128)
(sublane, lane); bf16 is (16, 128). Block shapes below are multiples of the
native tile so the MXU/VPU see hardware-aligned operands.
"""
from __future__ import annotations

import jax
import numpy as np

# Native tile geometry for fp32/int32 operands.
SUBLANE = 8
LANE = 128


def interpret_default() -> bool:
    """True when no TPU is attached (kernel body runs in Python)."""
    return jax.default_backend() != "tpu"


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def pad_axis(arr, axis: int, to: int, value=0):
    """Pad ``arr`` along ``axis`` up to length ``to`` with ``value``."""
    import jax.numpy as jnp

    cur = arr.shape[axis]
    if cur == to:
        return arr
    assert cur < to, (cur, to)
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, to - cur)
    return jnp.pad(arr, widths, constant_values=value)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def audit_avals(tree):
    """ShapeDtypeStruct mirror of a pytree of (device) arrays.

    The trace-level auditor (``repro.analysis.jaxpr_audit``) records
    program operands through this instead of keeping live buffers: avals
    are enough to retrace abstractly with ``jax.make_jaxpr``, retain no
    device memory, and — crucially — cause no transfer, so recording is
    invisible to the host-sync budget."""
    import jax.numpy as jnp

    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        tree)


def canonical_dtype(dt) -> np.dtype:
    """The dtype a host array actually has ON DEVICE under the current
    x64 regime: ``jnp.asarray`` silently narrows 64-bit widths when x64
    is off, which is exactly why device-byte accounting
    (``repro.analysis.memory_budget``) must not trust host ``nbytes``."""
    dt = np.dtype(dt)
    if jax.config.jax_enable_x64:
        return dt
    down = {"int64": np.int32, "uint64": np.uint32,
            "float64": np.float32, "complex128": np.complex64}
    return np.dtype(down.get(dt.name, dt))


def host_get(tree):
    """THE device→host transfer of the engine's device-resident paths.

    Every closing sync — the Generic-Join pipeline's landing
    (``core.backend``), the recursion fixpoints (``core.recursion``) and
    the materialize kernel's compacted extraction
    (``kernels.materialize.ops``) — routes through this one call site, so
    the static host-sync ratchet (``repro.analysis.sync_lint`` against
    ``sync_baseline.json``) audits exactly one ``device_get`` for the
    whole device path.  Adding a transfer anywhere else in the budgeted
    modules fails the linter; adding one here fails the baseline count.
    """
    return jax.device_get(tree)
