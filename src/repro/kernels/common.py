"""Shared Pallas kernel utilities.

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling). This container
is CPU-only, so ``interpret_default()`` flips every kernel into interpret
mode, which executes the kernel body in Python for correctness validation
against the pure-jnp oracles in each kernel's ``ref.py``.

TPU tiling notes (v5e): int32/float32 native VREG tile is (8, 128)
(sublane, lane); bf16 is (16, 128). Block shapes below are multiples of the
native tile so the MXU/VPU see hardware-aligned operands.
"""
from __future__ import annotations

import jax
import numpy as np

# Native tile geometry for fp32/int32 operands.
SUBLANE = 8
LANE = 128


def interpret_default() -> bool:
    """True when no TPU is attached (kernel body runs in Python)."""
    return jax.default_backend() != "tpu"


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def pad_axis(arr, axis: int, to: int, value=0):
    """Pad ``arr`` along ``axis`` up to length ``to`` with ``value``."""
    import jax.numpy as jnp

    cur = arr.shape[axis]
    if cur == to:
        return arr
    assert cur < to, (cur, to)
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, to - cur)
    return jnp.pad(arr, widths, constant_values=value)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def host_get(tree):
    """THE device→host transfer of the engine's device-resident paths.

    Every closing sync — the Generic-Join pipeline's landing
    (``core.backend``), the recursion fixpoints (``core.recursion``) and
    the materialize kernel's compacted extraction
    (``kernels.materialize.ops``) — routes through this one call site, so
    the static host-sync ratchet (``repro.analysis.sync_lint`` against
    ``sync_baseline.json``) audits exactly one ``device_get`` for the
    whole device path.  Adding a transfer anywhere else in the budgeted
    modules fails the linter; adding one here fails the baseline count.
    """
    return jax.device_get(tree)
