from repro.kernels.spmv_ell.ops import spmv_ell  # noqa: F401
