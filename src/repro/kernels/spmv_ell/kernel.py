"""Pallas TPU kernel: ELL-format SpMV (PageRank's y = A^T x hot loop).

PageRank in the engine is a (+, *) semiring join-aggregate over the edge
relation; its dense-math core is an SpMV. CSR rows are ragged — hostile to
fixed VMEM tiles — so rows are packed into ELL format (fixed K slots per
row, padded with column 0 / weight 0), giving a perfectly regular
(rows, K) gather + multiply + lane-reduce per tile.

  cols : [n, K] int32   column index per slot (pad -> 0)
  vals : [n, K] float32 weight per slot       (pad -> 0.0)
  x    : [n]    float32 input vector (resident in VMEM, whole)
  y    : [n]    float32 output, y[i] = sum_k vals[i,k] * x[cols[i,k]]

Grid over row tiles. The x gather uses jnp.take inside the kernel — on TPU
this lowers to a VMEM dynamic gather, the idiomatic equivalent of the
scalar-prefetch embedding pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv


def _kernel(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[...]                          # (rows, K)
    vals = vals_ref[...]
    x = x_ref[...]                                # (n,) whole vector
    gathered = jnp.take(x, cols, axis=0)          # (rows, K)
    y_ref[...] = (gathered * vals).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def spmv_ell_kernel(cols, vals, x, *, block_rows: int = 512,
                    interpret: bool = False):
    n, k = cols.shape
    assert vals.shape == (n, k) and n % block_rows == 0
    grid = (cdiv(n, block_rows),)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec(x.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(cols, vals, x)
