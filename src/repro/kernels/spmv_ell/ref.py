"""Pure-jnp oracle for the ELL SpMV kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def spmv_ell_ref(cols, vals, x):
    return (jnp.take(x, cols, axis=0) * vals).sum(axis=1)
