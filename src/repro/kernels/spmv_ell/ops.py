"""Jit'd public wrapper + CSR->ELL packing for the SpMV kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.common import interpret_default, round_up
from repro.kernels.spmv_ell.kernel import spmv_ell_kernel

_BLOCK_ROWS = 512


def csr_to_ell(offsets, neighbors, values=None, k: int | None = None):
    """Pack CSR into ELL (cols [n,K] int32, vals [n,K] f32). Rows longer
    than K must be pre-split by the caller (k defaults to max degree)."""
    offsets = np.asarray(offsets)
    neighbors = np.asarray(neighbors)
    n = len(offsets) - 1
    deg = np.diff(offsets)
    if k is None:
        k = int(deg.max()) if n else 1
    assert int(deg.max() if n else 0) <= k, "row exceeds ELL width"
    cols = np.zeros((n, k), dtype=np.int32)
    vals = np.zeros((n, k), dtype=np.float32)
    row = np.repeat(np.arange(n), deg)
    slot = np.arange(len(neighbors)) - np.repeat(offsets[:-1], deg)
    cols[row, slot] = neighbors
    vals[row, slot] = 1.0 if values is None else np.asarray(values, np.float32)
    return cols, vals


def spmv_ell(cols, vals, x, *, interpret=None):
    """y = sum_k vals[:, k] * x[cols[:, k]] with row padding handled."""
    if interpret is None:
        interpret = interpret_default()
    cols = jnp.asarray(cols, jnp.int32)
    vals = jnp.asarray(vals, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    n, k = cols.shape
    npad = round_up(max(n, _BLOCK_ROWS), _BLOCK_ROWS)
    kpad = round_up(max(k, 128), 128)
    if (npad, kpad) != (n, k):
        cols = jnp.zeros((npad, kpad), jnp.int32).at[:n, :k].set(cols)
        vals = jnp.zeros((npad, kpad), jnp.float32).at[:n, :k].set(vals)
    y = spmv_ell_kernel(cols, vals, x, block_rows=_BLOCK_ROWS,
                        interpret=interpret)
    return y[:n]
