"""JAX version compatibility shims (installed at ``repro.dist`` import).

The container pins jax 0.4.37; callers and tests are written against two
newer spellings:

  * ``AbstractMesh(axis_sizes, axis_names)`` — 0.4.37 only accepts the
    older ``AbstractMesh(shape_tuple)`` form with (name, size) pairs. We
    wrap ``__init__`` to accept both.
  * ``jax.set_mesh(mesh)`` — absent in 0.4.37. ``use_mesh`` (in
    act_sharding) is the supported spelling; it enters the plain ``Mesh``
    context manager, which is what activation-sharding helpers read.

Both shims are idempotent and purely additive: old-style calls behave
exactly as before.
"""
from __future__ import annotations

import numpy as np
from jax.sharding import AbstractMesh


def _is_sizes_names_call(shape_tuple, axis_types) -> bool:
    """True for the new-style AbstractMesh(axis_sizes, axis_names) call."""
    if not isinstance(shape_tuple, (tuple, list)) or not shape_tuple:
        return False
    if not all(isinstance(s, (int, np.integer)) for s in shape_tuple):
        return False
    return (isinstance(axis_types, (tuple, list)) and len(axis_types) ==
            len(shape_tuple) and all(isinstance(a, str) for a in axis_types))


def _install_abstract_mesh_shim():
    if getattr(AbstractMesh, "_repro_compat", False):
        return
    try:  # newer jax accepts (axis_sizes, axis_names) natively — no shim
        AbstractMesh((1,), ("probe",))
        return
    except Exception:
        pass
    orig_init = AbstractMesh.__init__

    def init(self, shape_tuple, axis_types=None, **kwargs):
        if _is_sizes_names_call(shape_tuple, axis_types):
            shape_tuple = tuple(zip(axis_types,
                                    (int(s) for s in shape_tuple)))
            axis_types = None
        orig_init(self, tuple(shape_tuple), axis_types, **kwargs)

    AbstractMesh.__init__ = init
    AbstractMesh._repro_compat = True


_install_abstract_mesh_shim()
