"""Mesh-context helpers for activation sharding inside model code.

Model functions call ``constrain(x, "batch", None, "model", ...)`` with
LOGICAL names; the mapping to mesh axes happens here, against the mesh
active at trace time (entered via ``use_mesh``). On the host CPU — no mesh,
or a 1-device mesh — every helper is a no-op, so the exact same model code
runs unsharded in unit tests.

``with_batch_axes(fn, axes)`` rebinds what 'batch' means for the duration
of one step function: MoE cells keep activations on ('pod', 'data') while
dense-FSDP cells spread them over ('pod', 'data', 'model').

Like the resolver in ``sharding``, constraints are shape-aware: 'batch'
composes its axes left-to-right and keeps the longest prefix that divides
the actual dim, so padded/odd batch dims degrade to replication instead of
failing to lower.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
from typing import Optional, Tuple

import jax
from jax._src import mesh as mesh_lib
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import compat  # noqa: F401
from repro.dist.sharding import assign_prefix

# Non-batch logical activation axes -> candidate mesh axes.
_ACT_RULES = {
    "expert": ("model",),     # EP all-to-all boundary in moe_ffn
    "model": ("model",),      # head/TP-sharded score & accumulator dims
    "heads": ("model",),
    "cache_seq": ("model",),  # context parallelism over the KV cache
}
_DEFAULT_BATCH_AXES: Tuple[str, ...] = ("pod", "data")
_batch_axes_var: contextvars.ContextVar = contextvars.ContextVar(
    "repro_batch_axes", default=_DEFAULT_BATCH_AXES)


def _current_mesh() -> Optional[Mesh]:
    """The mesh active for the current trace (see ``use_mesh``); None on
    the bare host."""
    mesh = mesh_lib.thread_resources.env.physical_mesh
    if mesh is None or mesh.empty:
        return None
    return mesh


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Activate ``mesh`` for tracing/lowering (jax 0.4.x spelling of the
    newer ``jax.set_mesh``): makes it visible to ``_current_mesh`` and to
    GSPMD sharding propagation."""
    with mesh:
        yield mesh


def current_batch_axes() -> Tuple[str, ...]:
    return _batch_axes_var.get()


def with_batch_axes(fn, axes: Tuple[str, ...]):
    """Wrap ``fn`` so that, while it runs (i.e. while it traces), the
    logical 'batch' axis maps to ``axes``."""
    axes = tuple(axes)

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        token = _batch_axes_var.set(axes)
        try:
            return fn(*args, **kwargs)
        finally:
            _batch_axes_var.reset(token)

    return wrapped


def model_axis_size() -> int:
    """Size of the 'model' mesh axis for the current trace (1 on host)."""
    mesh = _current_mesh()
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get("model", 1))


def constrain(x, *axes):
    """``with_sharding_constraint`` by logical axis names, one per dim
    (None = unconstrained). No-op without a multi-device mesh."""
    if len(axes) != x.ndim:  # checked even without a mesh, so the 1-device
        raise ValueError(    # unit tests catch malformed call sites
            f"constrain: {len(axes)} axis names for rank-{x.ndim} value")
    mesh = _current_mesh()
    if mesh is None or mesh.size <= 1:
        return x
    mesh_shape = dict(mesh.shape)
    entries: list = [None] * x.ndim
    used: set = set()
    for i, name in enumerate(axes):
        if name is None:
            continue
        cand = current_batch_axes() if name == "batch" \
            else _ACT_RULES.get(name, ())
        entries[i] = assign_prefix(x.shape[i], cand, mesh_shape, used)
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
