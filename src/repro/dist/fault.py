"""Fault tolerance for long runs: retry policy + checkpoint cadence.

At production scale (the 512-chip meshes in launch.mesh) step failures are
routine — preemptions, link flaps, transient RESOURCE_EXHAUSTED — and the
correct response is retry-then-resume, not crash. ``StepRunner`` wraps the
jitted step function with a bounded retry loop for failures classified
transient by ``FaultPolicy``, and owns the periodic-checkpoint cadence that
``train.loop`` pairs with auto-resume (restore latest step; the data
pipeline is deterministic in (seed, step), so the stream resumes exactly).

Raise ``TransientError`` from infrastructure code to force a retry;
anything whose message matches the policy's markers (the jaxlib/grpc status
strings seen on real clusters) is also retried. Everything else propagates
immediately — a NaN loss or shape error must never be retried into
oblivion.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional, Tuple

log = logging.getLogger("repro.dist.fault")


class TransientError(RuntimeError):
    """Explicitly retryable failure (preemption, flaky link, ...)."""


_TRANSIENT_MARKERS: Tuple[str, ...] = (
    "RESOURCE_EXHAUSTED", "UNAVAILABLE", "ABORTED", "DATA_LOSS",
    "DEADLINE_EXCEEDED", "preempt", "socket closed", "connection reset",
)


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """max_retries     retries per step before the failure propagates
    retry_wait_s      base sleep before a retry (doubles via ``backoff``)
    checkpoint_every  save cadence in steps (<= 0 disables periodic saves)
    """
    max_retries: int = 3
    retry_wait_s: float = 0.0
    backoff: float = 2.0
    checkpoint_every: int = 100
    transient_markers: Tuple[str, ...] = _TRANSIENT_MARKERS

    def is_transient(self, exc: BaseException) -> bool:
        if isinstance(exc, TransientError):
            return True
        msg = f"{type(exc).__name__}: {exc}".lower()
        return any(m.lower() in msg for m in self.transient_markers)


class StepRunner:
    """Executes ``step_fn(state, batch) -> (state, metrics)`` under a
    FaultPolicy, and saves checkpoints on the policy's cadence."""

    def __init__(self, step_fn: Callable, ckpt=None,
                 policy: Optional[FaultPolicy] = None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.policy = policy or FaultPolicy()
        self.retries_total = 0
        self.last_saved: Optional[int] = None

    def run(self, state, batch, step: int):
        attempt = 0
        while True:
            try:
                return self.step_fn(state, batch)
            except Exception as e:  # noqa: BLE001 — classified below
                if not self.policy.is_transient(e) \
                        or attempt >= self.policy.max_retries:
                    raise
                attempt += 1
                self.retries_total += 1
                wait = self.policy.retry_wait_s \
                    * self.policy.backoff ** (attempt - 1)
                log.warning("transient failure at step %d "
                            "(attempt %d/%d, retry in %.1fs): %s",
                            step, attempt, self.policy.max_retries, wait, e)
                if wait > 0:
                    time.sleep(wait)

    def maybe_checkpoint(self, state, step: int) -> bool:
        """Save iff ``step`` lands on the cadence; idempotent per step."""
        if self.ckpt is None or self.policy.checkpoint_every <= 0:
            return False
        if step % self.policy.checkpoint_every != 0 \
                or step == self.last_saved:
            return False
        self.ckpt.save(state, step)
        self.last_saved = step
        return True
