"""Distributed-execution layer: sharding rules, mesh context, fault policy.

Three orthogonal pieces, each consumed by a different layer of the stack:

  sharding      declarative rule tables (LM/GNN/recsys) + a shape-aware
                resolver mapping logical weight axes to mesh axes
                (used by launch.cells to build in/out shardings)
  act_sharding  mesh-context helpers for activation sharding constraints
                inside model code (no-ops on a 1-device mesh, so the same
                model functions run unsharded on the host CPU)
  fault         FaultPolicy + StepRunner: retry-on-transient-failure and
                checkpoint cadence for the training loop

``compat`` papers over jax 0.4.x vs 0.5.x API differences (AbstractMesh
constructor signature, the ``jax.set_mesh`` context) and is imported for
its side effects before anything else in the package.
"""
from repro.dist import compat  # noqa: F401  (installs jax 0.4.x shims)
from repro.dist.fault import FaultPolicy, StepRunner, TransientError  # noqa: F401
