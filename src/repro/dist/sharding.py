"""Declarative sharding rules + shape-aware resolver.

Model code names its weight dimensions with LOGICAL axes ("embed", "heads",
"mlp", "table_rows", ...; see each model's ``param_axes``). This module maps
those names to MESH axes ("pod", "data", "model") per family:

  LM_RULES             TP/EP over 'model' (heads / experts / vocab / mlp),
                       weight FSDP over 'data'
  LM_DENSE_FSDP_RULES  dense-arch training: no TP, weights 2-D-sharded over
                       ('data', 'model') — the pure-FSDP mapping
  GNN_RULES            feature-dim TP; GNN weights are small, so most fall
                       under the replication threshold
  RECSYS_RULES         row-sharded embedding tables over 'model'

Resolution is SHAPE-AWARE: a mesh axis is only assigned to a dim whose size
it divides; on failure the axis falls through the table's priority list to
the next eligible logical axis (e.g. 56 heads on a model=16 mesh fall back
to the embed dim). A mesh axis is never assigned twice in one spec, and
tensors smaller than ``fsdp_min_size`` elements are replicated outright —
collective overhead beats the bytes saved.

All functions take either a concrete ``jax.sharding.Mesh`` or an
``AbstractMesh`` (resolution only reads ``mesh.shape``), so specs can be
computed without touching devices.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Mapping, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.dist import compat  # noqa: F401  (AbstractMesh signature shim)

AxisEntry = Any  # None | str | tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """One family's axis-assignment policy.

    model_priority  logical axes eligible for the 'model' mesh axis (TP/EP),
                    most-preferred first; first divisible dim wins
    fsdp_priority   logical axes eligible for the fsdp mesh axes
    fsdp_axes       mesh axes bound by weight FSDP, in binding order
    batch_axes      mesh axes composing the data-parallel batch dim
                    (callers prepend 'pod' on 3-D meshes; see launch.cells)
    act_rules       logical activation/cache axis -> candidate mesh axes;
                    'batch' composes left-to-right ('pod','data') and keeps
                    the longest divisible prefix
    fsdp_min_size   element-count floor below which a tensor is replicated
    """
    name: str
    model_priority: Tuple[str, ...]
    fsdp_priority: Tuple[str, ...]
    fsdp_axes: Tuple[str, ...] = ("data",)
    batch_axes: Tuple[str, ...] = ("data",)
    act_rules: Mapping[str, Tuple[str, ...]] = \
        dataclasses.field(default_factory=dict)
    fsdp_min_size: int = 1 << 18


LM_RULES = ShardingRules(
    name="lm",
    model_priority=("expert", "heads", "kv_heads", "vocab", "mlp", "embed",
                    "qk_lora"),
    fsdp_priority=("embed", "mlp", "vocab", "qk_lora", "layer", "expert",
                   "head_dim"),
    act_rules={"batch": ("pod", "data"),
               "cache_seq": ("model",),
               "kv_heads": ("model",),
               "heads": ("model",)},
)

# Dense archs train pure-FSDP: both mesh axes shard weights, activations
# stay data-parallel over the whole mesh (no TP all-reduces on the forward
# pass — the 2-D mapping from the dry-run's worst-fraction analysis).
LM_DENSE_FSDP_RULES = ShardingRules(
    name="lm-dense-fsdp",
    model_priority=(),
    fsdp_priority=("embed", "mlp", "vocab", "qk_lora", "layer", "heads",
                   "head_dim"),
    fsdp_axes=("data", "model"),
    batch_axes=("data", "model"),
    act_rules={"batch": ("pod", "data", "model")},
)

GNN_RULES = ShardingRules(
    name="gnn",
    model_priority=("feat_out", "feat", "bilinear", "vocab", "basis"),
    fsdp_priority=("feat_in", "feat", "basis", "layer"),
    act_rules={"batch": ("pod", "data")},
)

RECSYS_RULES = ShardingRules(
    name="recsys",
    model_priority=("table_rows", "embed"),
    fsdp_priority=("table_rows",),
    act_rules={"batch": ("pod", "data")},
)


# ------------------------------------------------------------------ resolver
def _is_axes_leaf(x) -> bool:
    """Leaves of a param_axes tree: None (replicated) or a tuple of
    logical-axis names (Nones allowed per-dim; () for scalars)."""
    return x is None or (isinstance(x, tuple) and
                         all(e is None or isinstance(e, str) for e in x))


def _axes_used(entries) -> set:
    used = set()
    for e in entries:
        if e is None:
            continue
        used.update(e if isinstance(e, tuple) else (e,))
    return used


def assign_prefix(dim_size: int, candidates, mesh_shape, used: set):
    """Longest prefix of ``candidates`` (present in the mesh, unused so
    far) whose composed size divides ``dim_size``. Returns a spec entry —
    None, a bare axis name, or a tuple — and records taken axes in
    ``used``. Shared by the batch/cache resolver and act_sharding's
    ``constrain`` so the composition semantics live in one place."""
    cand = tuple(a for a in candidates if a in mesh_shape and a not in used)
    while cand and dim_size % math.prod(mesh_shape[a] for a in cand):
        cand = cand[:-1]
    if not cand:
        return None
    used.update(cand)
    return cand[0] if len(cand) == 1 else cand


def _resolve_one(axes, shape, mesh, rules: ShardingRules,
                 fsdp: bool = False) -> P:
    """Resolve one tensor's logical axes to a PartitionSpec.

    Assignment order: (1) 'model' to the highest-priority logical axis
    whose dim size it divides; (2) if ``fsdp``, each fsdp mesh axis to the
    highest-priority still-unassigned divisible dim. Small tensors
    (< fsdp_min_size elements) are replicated outright.
    """
    if axes is None:
        return P(*([None] * len(shape)))
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} do not match shape {shape}")
    if math.prod(shape) < rules.fsdp_min_size:
        return P(*([None] * len(shape)))
    mesh_shape = dict(mesh.shape)
    entries: list = [None] * len(shape)
    used: set = set()

    def assign(mesh_axis: str, priority: Tuple[str, ...]) -> None:
        if mesh_axis not in mesh_shape or mesh_axis in used:
            return
        size = mesh_shape[mesh_axis]
        for name in priority:
            if name not in axes:
                continue
            i = axes.index(name)
            if entries[i] is None and shape[i] % size == 0:
                entries[i] = mesh_axis
                used.add(mesh_axis)
                return

    assign("model", rules.model_priority)
    if fsdp:
        for ax in rules.fsdp_axes:
            assign(ax, rules.fsdp_priority)
    return P(*entries)


def resolve_param_specs(axes_tree, shapes_tree, mesh, rules: ShardingRules,
                        fsdp: bool = False):
    """Map a param_axes tree + matching ShapeDtypeStruct tree to a tree of
    PartitionSpecs (same structure as the params)."""
    return jax.tree.map(
        lambda a, s: _resolve_one(a, tuple(s.shape), mesh, rules, fsdp=fsdp),
        axes_tree, shapes_tree, is_leaf=_is_axes_leaf)


def _resolve_batch_one(axes, shape, mesh, rules: ShardingRules) -> P:
    """Activation/cache spec: each named dim takes the longest divisible
    prefix of its candidate mesh axes that doesn't reuse an axis."""
    if axes is None:
        return P(*([None] * len(shape)))
    if len(axes) != len(shape):
        raise ValueError(f"axes {axes} do not match shape {shape}")
    mesh_shape = dict(mesh.shape)
    entries: list = [None] * len(shape)
    used: set = set()
    for i, name in enumerate(axes):
        if name is None:
            continue
        entries[i] = assign_prefix(shape[i], rules.act_rules.get(name, ()),
                                   mesh_shape, used)
    return P(*entries)


def resolve_batch_specs(axes_tree, shapes_tree, mesh, rules: ShardingRules):
    """Resolve batch/cache trees (e.g. ``transformer.cache_axes``) where
    dims name activation axes like 'batch' and 'cache_seq'."""
    return jax.tree.map(
        lambda a, s: _resolve_batch_one(a, tuple(s.shape), mesh, rules),
        axes_tree, shapes_tree, is_leaf=_is_axes_leaf)


# -------------------------------------------------------------------- ZeRO-1
def zero1_axes(spec: P, mesh, rules: ShardingRules) -> Tuple[str, ...]:
    """Mesh axes available to further shard optimizer state for ``spec``:
    the ('pod',) + fsdp axes present in the mesh and unused by the spec."""
    used = _axes_used(spec)
    return tuple(a for a in ("pod",) + tuple(rules.fsdp_axes)
                 if a in dict(mesh.shape) and a not in used)


def zero1_specs(pspecs, shapes_tree, mesh, rules: ShardingRules):
    """Optimizer-state specs: params' specs plus a ZeRO-1 data-axis shard.

    For each tensor, bind the available batch-parallel axes (composed, or a
    suffix of them if the full composition doesn't divide any free dim) to
    the first unassigned divisible dim. Tensors below the replication
    threshold, or with no divisible free dim, keep the param spec.
    """
    mesh_shape = dict(mesh.shape)

    def one(spec, sds):
        shape = tuple(sds.shape)
        if spec is None:
            spec = P(*([None] * len(shape)))
        if math.prod(shape) < rules.fsdp_min_size:
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        trial = zero1_axes(spec, mesh, rules)
        while trial:
            total = math.prod(mesh_shape[a] for a in trial)
            for i, d in enumerate(shape):
                if entries[i] is None and d % total == 0:
                    entries[i] = trial[0] if len(trial) == 1 \
                        else tuple(trial)
                    return P(*entries)
            trial = trial[1:]
        return spec

    return jax.tree.map(one, pspecs, shapes_tree,
                        is_leaf=lambda x: x is None or isinstance(x, P))
