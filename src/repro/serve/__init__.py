"""Serving layer — two distinct entry points:

* :class:`repro.serve.engine.ServeEngine` — **models**: slot-based
  batched token serving through jitted prefill/decode step factories
  (``make_prefill_fn`` / ``make_decode_fn``).
* :class:`repro.serve.query.QueryServer` — **relational**: parameterized
  datalog queries over :class:`repro.core.engine.Engine` with cached
  physical plans, fused vmapped batch execution, and a multi-tenant
  :class:`repro.serve.query.GraphStore` with LRU device-cache eviction.

``ServeEngine`` is imported lazily: the relational server must work
without the models stack (and without pulling jax in at import time).
"""
from repro.serve.query import GraphStore, QueryServer, Ticket  # noqa: F401

__all__ = ["GraphStore", "QueryServer", "Ticket",
           "ServeEngine", "make_decode_fn", "make_prefill_fn",
           "batched_scores"]

_ENGINE_EXPORTS = ("ServeEngine", "make_decode_fn", "make_prefill_fn",
                   "batched_scores")


def __getattr__(name):
    if name in _ENGINE_EXPORTS:
        from repro.serve import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
