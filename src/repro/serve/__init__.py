from repro.serve.engine import ServeEngine, make_decode_fn, make_prefill_fn  # noqa: F401
