"""Relational query serving: parameterized plans, batched execution,
multi-tenant graph store.

The models half of the serve package (``serve.engine.ServeEngine``)
batches token requests through jitted prefill/decode steps.  This module
is its relational analog over :class:`repro.core.engine.Engine`:

  * **Parameterized queries** — ``QueryServer.prepare`` compiles a rule
    ONCE with its selection constants rewritten into bind slots
    (``compile.parameterize``); re-binding reuses the cached logical
    plan, plan-search decision, physical plan + emitted source, and the
    backend's traced bag programs.  Zero plan searches and zero retraces
    per re-bind — the ``compile.*`` counters and
    ``backend.trace_count()`` prove it.
  * **Batched execution** — ``submit`` + ``drain`` group admitted
    requests by prepared query and execute each group through
    ``PreparedQuery.run_batch``: B same-shape probes become ONE fused
    vmapped device launch per ``statistics.max_batch`` chunk
    (``pipeline.batched_launches``), with the sequential per-binding
    loop as the exact-parity fallback on host backends or non-batchable
    plan shapes.
  * **Multi-tenant graph store** — several graphs resident at once, one
    ``Engine`` (catalog + plan caches) per tenant over ONE shared
    backend, with LRU eviction over the trie device-upload cache: when
    the resident-byte budget (or graph count) is exceeded, the coldest
    tenant's tries drop their device-resident copies
    (``Trie.evict_device``).  Eviction is a cache policy, not data
    loss — the host tries stay loaded and re-upload lazily on the
    tenant's next query.

Per-tenant dispatch counters (``tenant.<t>.queries`` / ``.batches`` /
``.evictions``) and store-wide counters (``store.evictions``,
``queue.admitted`` / ``queue.drained``) live in ``QueryServer.counters``;
``benchmarks/serve_bench.py`` gates on them in CI.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict

from repro.analysis.concurrency_lint import guarded_by
from repro.core.backend import ExecBackend, make_backend
from repro.core.engine import Engine, PreparedQuery, QueryResult
from repro.core.trie import Trie


@dataclasses.dataclass
class Ticket:
    """Admission handle for one submitted query: filled by ``drain``."""

    tenant: str
    params: tuple[object, ...]
    result: QueryResult | None = None
    done: bool = False


@dataclasses.dataclass
class _Pending:
    ticket: Ticket
    prepared: PreparedQuery


class GraphStore:
    """LRU residency manager over the tries of several tenant graphs.

    Tracks which tenant was queried least recently and, when the
    device-resident byte budget (``capacity_bytes``) or the resident
    graph count (``max_graphs``) is exceeded, evicts the coldest
    tenant's device caches via :meth:`repro.core.trie.Trie.evict_device`.
    The most recently touched tenant is never evicted.

    The byte budget is accounted in MODEL device bytes
    (``analysis.memory_budget.trie_device_bytes``): host ``nbytes()``
    counts int64 offsets the device never holds (x64 off narrows them
    to int32 on upload) and misses the bitset block directories
    entirely, so budgeting on it would over- or under-evict.

    Thread safety: every public method takes ``self._lock`` (re-entrant
    — ``enforce`` reads residency while holding it); the two
    ``@guarded_by`` helpers document that their callers must already
    hold it.  The discipline is machine-checked by
    ``analysis.concurrency_lint``.
    """

    def __init__(self, capacity_bytes: int | None = None,
                 max_graphs: int | None = None):
        self.capacity_bytes = capacity_bytes
        self.max_graphs = max_graphs
        self._lock = threading.RLock()
        # tenant -> registered tries, in LRU order (first = coldest)
        self._tries: OrderedDict[str, list[Trie]] = OrderedDict()
        self.evictions = 0

    def register(self, tenant: str, trie: Trie) -> None:
        with self._lock:
            self._tries.setdefault(tenant, []).append(trie)
            self._tries.move_to_end(tenant)

    def touch(self, tenant: str) -> None:
        with self._lock:
            if tenant in self._tries:
                self._tries.move_to_end(tenant)

    def tenants(self) -> list[str]:
        """Tenants in LRU order (coldest first)."""
        with self._lock:
            return list(self._tries)

    def resident(self, tenant: str) -> bool:
        with self._lock:
            return any(t.device_resident
                       for t in self._tries.get(tenant, ()))

    def resident_bytes(self) -> int:
        """MODEL device bytes of every resident trie (what eviction
        would actually reclaim), not host ``nbytes()``."""
        from repro.analysis.memory_budget import trie_device_bytes
        with self._lock:
            return sum(trie_device_bytes(t) for ts in self._tries.values()
                       for t in ts if t.device_resident)

    @guarded_by("_lock")
    def _resident_tenants(self) -> list[str]:
        return [t for t in self._tries if self.resident(t)]

    @guarded_by("_lock")
    def _over_budget(self) -> bool:
        if self.max_graphs is not None \
                and len(self._resident_tenants()) > self.max_graphs:
            return True
        return self.capacity_bytes is not None \
            and self.resident_bytes() > self.capacity_bytes

    def enforce(self) -> list[str]:
        """Evict coldest-first until within budget; returns the evicted
        tenants.  The warmest resident tenant always survives (evicting
        the graph that was just queried would thrash)."""
        evicted: list[str] = []
        with self._lock:
            while self._over_budget():
                resident = self._resident_tenants()
                if len(resident) <= 1:
                    break
                cold = resident[0]
                for t in self._tries[cold]:
                    t.evict_device()
                self.evictions += 1
                evicted.append(cold)
        return evicted


class QueryServer:
    """Serve relational queries for several tenant graphs.

    One :class:`~repro.core.engine.Engine` per tenant (separate catalogs
    and plan caches — tenants cannot read each other's relations) over
    ONE shared backend (shared kernel dispatch, traced-program cache,
    and counters).  ``prepare``/``run`` serve point queries with
    bind-parameter plan reuse; ``submit``/``drain`` run an admission
    queue whose per-prepared-query groups execute as fused batches.

    Thread safety: the server's own shared state (admission queue,
    per-tenant engine and prepared-query maps, counters) is guarded by
    ``self._lock`` (re-entrant: locked paths call ``_bump`` and
    ``prepare``).  ``drain`` swaps the queue out under the lock and
    executes OUTSIDE it, so a long batch never blocks admission.  The
    engines and backend themselves are single-threaded per instance
    (their caches are in ``concurrency_lint``'s accounted baseline) —
    concurrent queries against the SAME tenant must be serialized by
    the caller; the lock here makes admission, preparation and the
    store's LRU/byte accounting safe across tenants.
    """

    def __init__(self, backend=None, capacity_bytes: int | None = None,
                 max_graphs: int | None = None, **engine_opts):
        self.backend: ExecBackend = make_backend(backend)
        self.store = GraphStore(capacity_bytes=capacity_bytes,
                                max_graphs=max_graphs)
        self._engine_opts = dict(engine_opts)
        self._lock = threading.RLock()
        self._engines: dict[str, Engine] = {}
        self._prepared: dict[tuple[str, str], PreparedQuery] = {}
        self._queue: list[_Pending] = []
        self.counters: dict[str, int] = {}

    # ------------------------------------------------------------- tenants
    def _bump(self, key: str, n: int = 1) -> None:
        with self._lock:
            self.counters[key] = self.counters.get(key, 0) + n

    def engine(self, tenant: str) -> Engine:
        with self._lock:
            eng = self._engines.get(tenant)
            if eng is None:
                eng = Engine(backend=self.backend, **self._engine_opts)
                self._engines[tenant] = eng
            return eng

    def load_graph(self, tenant: str, name: str, src, dst,
                   annotation=None) -> Trie:
        t = self.engine(tenant).load_edges(name, src, dst,
                                           annotation=annotation)
        self.store.register(tenant, t)
        self._evict_over_budget()
        return t

    def load_table(self, tenant: str, name: str, columns,
                   annotation=None) -> Trie:
        t = self.engine(tenant).load_table(name, columns,
                                           annotation=annotation)
        self.store.register(tenant, t)
        self._evict_over_budget()
        return t

    def alias(self, tenant: str, name: str, target: str) -> None:
        self.engine(tenant).alias(name, target)

    def _evict_over_budget(self) -> None:
        for cold in self.store.enforce():
            self._bump(f"tenant.{cold}.evictions")
            self._bump("store.evictions")

    # ------------------------------------------------------------- queries
    def prepare(self, tenant: str, text: str) -> PreparedQuery:
        with self._lock:
            key = (tenant, text)
            pq = self._prepared.get(key)
            if pq is None:
                pq = self.engine(tenant).prepare(text)
                self._prepared[key] = pq
            return pq

    def run(self, tenant: str, text: str, *params) -> QueryResult:
        """Point query through the prepared-plan cache: the first call
        per (tenant, text) compiles; every later call only re-binds."""
        pq = self.prepare(tenant, text)
        self.store.touch(tenant)
        res = pq.run(*params)
        self._bump(f"tenant.{tenant}.queries")
        self._evict_over_budget()
        return res

    def query(self, tenant: str, text: str) -> QueryResult:
        """Unparameterized passthrough (multi-rule programs, recursion)."""
        self.store.touch(tenant)
        res = self.engine(tenant).query(text)
        self._bump(f"tenant.{tenant}.queries")
        self._evict_over_budget()
        return res

    # ---------------------------------------------------- admission queue
    def submit(self, tenant: str, text: str, *params) -> Ticket:
        """Admit one query; execution is deferred to :meth:`drain` so
        same-shape requests can share a fused batched launch."""
        pq = self.prepare(tenant, text)
        ticket = Ticket(tenant=tenant, params=pq._binding(params))
        with self._lock:
            self._queue.append(_Pending(ticket=ticket, prepared=pq))
        self._bump("queue.admitted")
        return ticket

    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    def drain(self) -> list[Ticket]:
        """Execute every admitted request, grouped by prepared query:
        each group runs through ``PreparedQuery.run_batch`` (one fused
        launch per same-shape chunk on the device backend, sequential
        parity loop elsewhere).  Tickets are filled in admission order.
        The queue is swapped out under the lock; execution happens
        outside it so a long batch never blocks admission."""
        with self._lock:
            queue, self._queue = self._queue, []
        groups: OrderedDict[int, list[_Pending]] = OrderedDict()
        for p in queue:
            groups.setdefault(id(p.prepared), []).append(p)
        for members in groups.values():
            pq = members[0].prepared
            tenant = members[0].ticket.tenant
            self.store.touch(tenant)
            results = pq.run_batch([p.ticket.params for p in members])
            for p, res in zip(members, results):
                p.ticket.result = res
                p.ticket.done = True
            self._bump(f"tenant.{tenant}.queries", len(members))
            if len(members) > 1:
                self._bump(f"tenant.{tenant}.batches")
            self._evict_over_budget()
        self._bump("queue.drained", len(queue))
        return [p.ticket for p in queue]

    # ------------------------------------------------------------- stats
    def dispatch_summary(self) -> dict[str, int]:
        """Shared-backend dispatch counters merged with the server's
        per-tenant and queue counters."""
        out = dict(self.backend.dispatch_summary())
        out.update(self.counters)
        return out
