"""Model serving: jitted prefill/decode step factories + a batched token
request engine (continuous batching lite: fixed batch slots, per-slot
lengths).

This is the MODELS half of the serve package — :class:`ServeEngine`
batches token-generation requests against transformer weights.  The
RELATIONAL half, serving datalog queries over graph catalogs with
parameterized plans and fused batch execution, is its sibling
:class:`repro.serve.query.QueryServer`.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm


def make_prefill_fn(cfg, max_len: int):
    @jax.jit
    def fn(params, tokens):
        return tfm.prefill(params, tokens, cfg, max_len)
    return fn


def make_decode_fn(cfg):
    step = tfm.decode_step_mla if cfg.attention == "mla" else tfm.decode_step

    @jax.jit
    def fn(params, cache, tokens):
        return step(params, cache, tokens, cfg)
    return fn


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16
    out: list[int] | None = None


class ServeEngine:
    """Slot-based batched serving: requests share a fixed-batch KV cache;
    greedy decode; finished slots are refilled from the queue."""

    def __init__(self, params, cfg, batch_slots: int, max_len: int):
        self.params = params
        self.cfg = cfg
        self.batch = batch_slots
        self.max_len = max_len
        self.prefill = make_prefill_fn(cfg, max_len)
        self.decode = make_decode_fn(cfg)

    def run(self, requests: list[Request]) -> list[list[int]]:
        """Static batching MVP: pad prompts to a common length per wave."""
        outs: list[list[int]] = []
        for s in range(0, len(requests), self.batch):
            wave = requests[s:s + self.batch]
            outs.extend(self._run_wave(wave))
        return outs

    def _run_wave(self, wave: list[Request]) -> list[list[int]]:
        b = len(wave)
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((self.batch, plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        logits, cache = self.prefill(self.params, jnp.asarray(toks))
        new = jnp.argmax(logits, axis=-1)
        results = [[int(new[i])] for i in range(b)]
        steps = max(r.max_new_tokens for r in wave)
        for _ in range(steps - 1):
            logits, cache = self.decode(self.params, cache, new[:, None])
            new = jnp.argmax(logits, axis=-1)
            for i in range(b):
                if len(results[i]) < wave[i].max_new_tokens:
                    results[i].append(int(new[i]))
        return results


def batched_scores(score_fn: Callable, inputs, batch: int):
    """Offline bulk scoring helper: chunk a big input table through a jitted
    scorer (recsys serve_bulk path)."""
    n = len(jax.tree.leaves(inputs)[0])
    outs = []
    for s in range(0, n, batch):
        chunk = jax.tree.map(lambda x, s=s: x[s:s + batch], inputs)
        outs.append(np.asarray(score_fn(chunk)))
    return np.concatenate(outs)
