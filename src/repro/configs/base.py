"""Config-system core: the Cell abstraction every (arch x shape) pair
lowers through.

Each arch module exposes ``ARCH: ArchDef``. A Cell names (arch, shape,
step kind); ``repro.launch.cells`` turns a Cell into the concrete
(fn, example inputs as ShapeDtypeStructs, shardings) triple that
``launch/dryrun.py`` lowers and compiles.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeDef:
    name: str
    kind: str                    # train | prefill | decode | score | retrieval
    params: Dict[str, Any]
    skip: Optional[str] = None   # reason string if this cell is N/A


@dataclasses.dataclass
class ArchDef:
    name: str
    family: str                  # lm | gnn | recsys | engine
    tag: str                     # dense | moe | gnn | recsys | engine
    config: Any                  # model config dataclass
    shapes: Dict[str, ShapeDef]
    source: str                  # provenance citation
    notes: str = ""

    def shape(self, name: str) -> ShapeDef:
        return self.shapes[name]


# ---------------------------------------------------------------- LM shapes
def lm_shapes(attention: str, window: Optional[int] = None,
              sub_quadratic_decode: bool = False) -> Dict[str, ShapeDef]:
    """The four assigned LM shapes. ``long_500k`` needs a sub-quadratic
    attention/cache mechanism; pure full-attention archs skip it (recorded
    reason surfaces in EXPERIMENTS.md)."""
    shapes = {
        "train_4k": ShapeDef("train_4k", "train",
                             {"seq_len": 4096, "global_batch": 256}),
        "prefill_32k": ShapeDef("prefill_32k", "prefill",
                                {"seq_len": 32768, "global_batch": 32}),
        "decode_32k": ShapeDef("decode_32k", "decode",
                               {"seq_len": 32768, "global_batch": 128}),
    }
    if sub_quadratic_decode:
        shapes["long_500k"] = ShapeDef(
            "long_500k", "decode", {"seq_len": 524288, "global_batch": 1})
    else:
        shapes["long_500k"] = ShapeDef(
            "long_500k", "decode", {"seq_len": 524288, "global_batch": 1},
            skip=f"pure full-attention arch ({attention}): 500k decode "
                 "requires a sub-quadratic attention/cache mechanism")
    return shapes


# --------------------------------------------------------------- GNN shapes
def gnn_shapes() -> Dict[str, ShapeDef]:
    return {
        "full_graph_sm": ShapeDef(
            "full_graph_sm", "train",
            {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
             "n_classes": 7}),
        "minibatch_lg": ShapeDef(
            "minibatch_lg", "train",
            {"n_nodes": 232_965, "n_edges": 114_615_892, "d_feat": 602,
             "n_classes": 41, "batch_nodes": 1024, "fanout": (15, 10)}),
        "ogb_products": ShapeDef(
            "ogb_products", "train",
            {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100,
             "n_classes": 47}),
        "molecule": ShapeDef(
            "molecule", "train",
            {"n_nodes": 30, "n_edges": 64, "batch": 128}),
    }


# ------------------------------------------------------------ recsys shapes
def recsys_shapes() -> Dict[str, ShapeDef]:
    return {
        "train_batch": ShapeDef("train_batch", "train", {"batch": 65536}),
        "serve_p99": ShapeDef("serve_p99", "score", {"batch": 512}),
        "serve_bulk": ShapeDef("serve_bulk", "score", {"batch": 262144}),
        "retrieval_cand": ShapeDef("retrieval_cand", "retrieval",
                                   {"batch": 1, "n_candidates": 1_000_000}),
    }
