"""Config registry: ``--arch <id>`` resolution for launchers/tests.

Ten assigned architectures + the paper's own engine config.
"""
from typing import Dict

from repro.configs.base import ArchDef

from repro.configs import (  # noqa: E402
    arctic_480b, dimenet, emptyheaded, fm, gcn_cora, granite_3_8b, mace,
    minicpm3_4b, mixtral_8x7b, nequip, qwen2_72b,
)

REGISTRY: Dict[str, ArchDef] = {
    m.ARCH.name: m.ARCH
    for m in (arctic_480b, mixtral_8x7b, granite_3_8b, qwen2_72b,
              minicpm3_4b, dimenet, gcn_cora, nequip, mace, fm, emptyheaded)
}

ASSIGNED = [n for n in REGISTRY if n != "emptyheaded"]


def get_arch(name: str) -> ArchDef:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def all_cells(include_skipped: bool = False):
    """Every (arch, shape) pair in the assignment (+ engine)."""
    out = []
    for arch in REGISTRY.values():
        for shape in arch.shapes.values():
            if shape.skip and not include_skipped:
                continue
            out.append((arch.name, shape.name))
    return out
