"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155. [hf:ibm-granite/granite-3.0-2b-base; hf]
"""
import jax.numpy as jnp

from repro.configs.base import ArchDef, lm_shapes
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="granite-3-8b",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12800, vocab=49155, d_head=128,
    attention="full",
    dtype=jnp.bfloat16, remat="dots",
)

ARCH = ArchDef(
    name="granite-3-8b", family="lm", tag="dense", config=CONFIG,
    shapes=lm_shapes("full", sub_quadratic_decode=False),
    source="hf:ibm-granite/granite-3.0-2b-base",
    notes="GQA kv=8",
)
