"""fm [recsys] — n_sparse=39 embed_dim=10 interaction=fm-2way.
[ICDM'10 (Rendle); paper]

Embedding tables: 39 fields x 1M rows x dim 10 (the 10^6-row-per-field
regime of the taxonomy), row-sharded over 'model'. The FM interaction is
the Pallas ``fm_interaction`` kernel (sum-square trick).
"""
from repro.configs.base import ArchDef, recsys_shapes
from repro.models.recsys.fm import FMConfig

CONFIG = FMConfig(
    name="fm", n_sparse=39, vocab_per_field=1_000_000, embed_dim=10,
    interaction="fm-2way",
)

ARCH = ArchDef(
    name="fm", family="recsys", tag="recsys", config=CONFIG,
    shapes=recsys_shapes(),
    source="ICDM'10 (Rendle)",
    notes="EmbeddingBag = take + segment_sum; retrieval = batched dot",
)
