"""nequip [gnn] — n_layers=5 d_hidden=32 l_max=2 n_rbf=8 cutoff=5,
E(3) tensor-product equivariance. [arXiv:2101.03164; paper]
"""
from repro.configs.base import ArchDef, gnn_shapes
from repro.models.gnn.equivariant import NequIPConfig

CONFIG = NequIPConfig(
    name="nequip", n_layers=5, d_hidden=32, l_max=2, n_rbf=8, cutoff=5.0,
)

ARCH = ArchDef(
    name="nequip", family="gnn", tag="gnn", config=CONFIG,
    shapes=gnn_shapes(),
    source="arXiv:2101.03164",
    notes="irrep tensor-product regime; exact real-CG algebra in-repo",
)
