"""arctic-480b [moe] — Snowflake Arctic-style dense-MoE hybrid.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2 with
a dense residual FFN in parallel. [hf:Snowflake/snowflake-arctic-base; hf]

Sharding note: 56 heads are not divisible by model=16, so the shape-aware
resolver falls back to sharding the attention embed dim over 'model'
(DESIGN.md §4); experts shard 8-per-chip over 'model' (EP) with weight FSDP
over 'data'.
"""
import jax.numpy as jnp

from repro.configs.base import ArchDef, lm_shapes
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="arctic-480b",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, d_head=128,
    attention="full",
    n_experts=128, top_k=2, dense_residual=True,
    dtype=jnp.bfloat16, remat="full",
)

ARCH = ArchDef(
    name="arctic-480b", family="lm", tag="moe", config=CONFIG,
    shapes=lm_shapes("full", sub_quadratic_decode=False),
    source="hf:Snowflake/snowflake-arctic-base",
    notes="128 experts top-2 + dense residual FFN",
)
