"""minicpm3-4b [dense] — 62L d_model=2560 40H d_ff=6400 vocab=73448 with
MLA (multi-head latent attention). [hf:openbmb/MiniCPM3-4B; hf]

MLA dims follow the HF config: q_lora_rank=768, kv_lora_rank=256,
qk_nope=64, qk_rope=32, v_head=64. The decode cache stores the compressed
latent (256 + 32 per token instead of 2*40*96) — but prefill/score compute
is still full quadratic attention, so ``long_500k`` is skipped (the skip
reason names MLA as cache-compressed, not sub-quadratic).
"""
import jax.numpy as jnp

from repro.configs.base import ArchDef, lm_shapes
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="minicpm3-4b",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab=73448,
    attention="mla",
    q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    dtype=jnp.bfloat16, remat="dots",
)

ARCH = ArchDef(
    name="minicpm3-4b", family="lm", tag="dense", config=CONFIG,
    shapes=lm_shapes("mla (latent-compressed cache, still full quadratic)",
                     sub_quadratic_decode=False),
    source="hf:openbmb/MiniCPM3-4B",
    notes="MLA",
)
