"""emptyheaded [engine] — the paper's own engine as a distributable config.

The dry-run cell is edge-parallel triangle counting: edges sharded over the
whole mesh, padded-ELL adjacency replicated, per-shard membership-test
intersections (the uint∩uint kernel formulation), psum of the count —
i.e. the paper's 48-thread shared-memory parallelism mapped onto a
512-chip mesh. This is the "most representative of the paper's technique"
hillclimb cell (EXPERIMENTS.md §Perf).
"""
import dataclasses

from repro.configs.base import ArchDef, ShapeDef


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    name: str = "emptyheaded"
    n_nodes: int = 1 << 22        # 4.2M nodes
    n_edges: int = 1 << 27        # 134M directed edges
    ell_width: int = 64           # padded adjacency width (dense cohort cap)

    def param_count(self) -> int:
        return 0


CONFIG = EngineConfig()

ARCH = ArchDef(
    name="emptyheaded", family="engine", tag="engine", config=CONFIG,
    shapes={
        "triangle_lg": ShapeDef(
            "triangle_lg", "engine",
            {"n_nodes": CONFIG.n_nodes, "n_edges": CONFIG.n_edges,
             "ell_width": CONFIG.ell_width}),
    },
    source="this paper",
    notes="WCOJ triangle count distributed over the production mesh",
)
