"""gcn-cora [gnn] — 2L d_hidden=16 aggregator=mean norm=sym.
[arXiv:1609.02907; paper]

Direct application of the paper's technique: a GCN layer is a
(+, *)-semiring join-aggregate over the Edge relation (DESIGN.md §5) —
differentially tested against the EmptyHeaded engine in tests/.
"""
from repro.configs.base import ArchDef, gnn_shapes
from repro.models.gnn.gcn import GCNConfig

CONFIG = GCNConfig(
    name="gcn-cora", n_layers=2, d_hidden=16, d_feat=1433, n_classes=7,
    aggregator="mean", norm="sym",
)

ARCH = ArchDef(
    name="gcn-cora", family="gnn", tag="gnn", config=CONFIG,
    shapes=gnn_shapes(),
    source="arXiv:1609.02907",
    notes="SpMM regime; d_feat/n_classes follow each shape's dataset",
)
