"""mace [gnn] — n_layers=2 d_hidden=128 l_max=2 correlation_order=3 n_rbf=8,
E(3)-ACE higher-order message passing. [arXiv:2206.07697; paper]
"""
from repro.configs.base import ArchDef, gnn_shapes
from repro.models.gnn.equivariant import MACEConfig

CONFIG = MACEConfig(
    name="mace", n_layers=2, d_hidden=128, l_max=2, correlation_order=3,
    n_rbf=8, cutoff=5.0,
)

ARCH = ArchDef(
    name="mace", family="gnn", tag="gnn", config=CONFIG,
    shapes=gnn_shapes(),
    source="arXiv:2206.07697",
    notes="ACE product basis via iterated CG (DESIGN.md deviation note)",
)
