"""qwen2-72b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, QKV bias. [arXiv:2407.10671; hf]
"""
import jax.numpy as jnp

from repro.configs.base import ArchDef, lm_shapes
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen2-72b",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, d_head=128,
    attention="full", qkv_bias=True, rope_theta=1e6,
    dtype=jnp.bfloat16, remat="full",
)

ARCH = ArchDef(
    name="qwen2-72b", family="lm", tag="dense", config=CONFIG,
    shapes=lm_shapes("full", sub_quadratic_decode=False),
    source="arXiv:2407.10671",
    notes="GQA kv=8, QKV bias",
)
