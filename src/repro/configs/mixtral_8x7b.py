"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, sliding-window attention (4096).
[arXiv:2401.04088; hf]

SWA gives the windowed KV cache, so mixtral is the one assigned LM arch
that RUNS ``long_500k`` (O(window) decode cache).
"""
import jax.numpy as jnp

from repro.configs.base import ArchDef, lm_shapes
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="mixtral-8x7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, d_head=128,
    attention="swa", window=4096,
    n_experts=8, top_k=2,
    dtype=jnp.bfloat16, remat="dots",
)

ARCH = ArchDef(
    name="mixtral-8x7b", family="lm", tag="moe", config=CONFIG,
    shapes=lm_shapes("swa", window=4096, sub_quadratic_decode=True),
    source="arXiv:2401.04088",
    notes="8 experts top-2, SWA window 4096",
)
