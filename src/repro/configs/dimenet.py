"""dimenet [gnn] — n_blocks=6 d_hidden=128 n_bilinear=8 n_spherical=7
n_radial=6. [arXiv:2003.03123; unverified]

Triplet-gather regime: the wedge join (k->j->i) is a 3-way self-join of
Edge — the paper's WCOJ machinery computes exactly this (see
benchmarks + tests for the differential check on small graphs). Non-
molecular shapes get synthetic 3D positions from the data pipeline
(frontend stub; DESIGN.md §5).
"""
from repro.configs.base import ArchDef, gnn_shapes
from repro.models.gnn.dimenet import DimeNetConfig

CONFIG = DimeNetConfig(
    name="dimenet", n_blocks=6, d_hidden=128, n_bilinear=8,
    n_spherical=7, n_radial=6, cutoff=5.0,
)

# triplet budget per shape (T ~= sum_j d_in(j) d_out(j); capped for the
# social-graph shapes, cap reported by the pipeline — no silent truncation)
TRIPLET_FACTOR = {"full_graph_sm": 24, "minibatch_lg": 4, "ogb_products": 4,
                  "molecule": 16}

ARCH = ArchDef(
    name="dimenet", family="gnn", tag="gnn", config=CONFIG,
    shapes=gnn_shapes(),
    source="arXiv:2003.03123",
    notes="triplet gather; positions synthetic on non-molecular shapes",
)
