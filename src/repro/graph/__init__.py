"""Graph preprocessing substrate: dictionary encoding, node orderings,
symmetric pruning, and skew statistics (paper Section 2.2 + Appendix C.2)."""
from repro.graph.dictionary import Dictionary, encode_edges  # noqa: F401
from repro.graph.ordering import ORDERINGS, apply_ordering, order_nodes  # noqa: F401
from repro.graph.prune import prune_symmetric, symmetrize  # noqa: F401
from repro.graph.stats import density_skew, graph_stats  # noqa: F401
