"""Node orderings (paper Appendix C.2.1).

Node ordering changes the ranges of the neighbor sets (and hence the layout
optimizer's decisions) and, for symmetric queries with pruning, the number of
comparisons. Orderings implemented, as in Table 11:

  random, bfs, degree (descending), revdegree (ascending), strongruns,
  shingle, hybrid (BFS then stable-sorted by descending degree).
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from repro.core.trie import CSRGraph


def _perm_from_rank(rank: np.ndarray) -> np.ndarray:
    """rank[i] = sort key of node i -> perm[i] = new id of node i."""
    order = np.argsort(rank, kind="stable")
    perm = np.empty_like(order)
    perm[order] = np.arange(len(order))
    return perm


def order_random(csr: CSRGraph, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.permutation(csr.n)


def order_degree(csr: CSRGraph, seed: int = 0) -> np.ndarray:
    """Descending degree (the paper's default standard)."""
    return _perm_from_rank(-csr.degrees)


def order_revdegree(csr: CSRGraph, seed: int = 0) -> np.ndarray:
    return _perm_from_rank(csr.degrees)


def order_bfs(csr: CSRGraph, seed: int = 0) -> np.ndarray:
    """Breadth-first labeling from the highest-degree node of each component."""
    n = csr.n
    label = np.full(n, -1, dtype=np.int64)
    nxt = 0
    by_deg = np.argsort(-csr.degrees, kind="stable")
    for root in by_deg:
        if label[root] >= 0:
            continue
        frontier = np.array([root], dtype=np.int64)
        label[root] = nxt
        nxt += 1
        while len(frontier):
            nbrs = np.concatenate([csr.neighbors_of(int(u)) for u in frontier]) \
                if len(frontier) else np.zeros(0, np.int64)
            nbrs = np.unique(nbrs.astype(np.int64))
            new = nbrs[label[nbrs] < 0]
            label[new] = nxt + np.arange(len(new))
            nxt += len(new)
            frontier = new
    return label


def order_strongruns(csr: CSRGraph, seed: int = 0) -> np.ndarray:
    """Sort by degree, then assign continuous ids to each node's neighbors
    starting from the highest-degree node (approximates BFS; paper C.2.1)."""
    n = csr.n
    label = np.full(n, -1, dtype=np.int64)
    nxt = 0
    for u in np.argsort(-csr.degrees, kind="stable"):
        if label[u] < 0:
            label[u] = nxt
            nxt += 1
        for v in csr.neighbors_of(int(u)):
            if label[v] < 0:
                label[v] = nxt
                nxt += 1
    return label


def order_shingle(csr: CSRGraph, seed: int = 0) -> np.ndarray:
    """Shingle ordering [Chierichetti et al., KDD'09]: order nodes by the
    minhash of their neighborhood so similar neighborhoods get nearby ids."""
    rng = np.random.default_rng(seed)
    a = rng.integers(1, 1 << 31, dtype=np.int64)
    b = rng.integers(0, 1 << 31, dtype=np.int64)
    m = (1 << 31) - 1
    h = (a * csr.neighbors.astype(np.int64) + b) % m
    minhash = np.full(csr.n, np.iinfo(np.int64).max)
    src = np.repeat(np.arange(csr.n), csr.degrees)
    np.minimum.at(minhash, src, h)
    return _perm_from_rank(minhash)


def order_hybrid(csr: CSRGraph, seed: int = 0) -> np.ndarray:
    """Paper's proposed hybrid: BFS labels, then stable sort by descending
    degree (equal-degree nodes retain BFS order)."""
    bfs = order_bfs(csr, seed)
    # stable sort by (-degree, bfs)
    order = np.lexsort((bfs, -csr.degrees))
    perm = np.empty(csr.n, dtype=np.int64)
    perm[order] = np.arange(csr.n)
    return perm


ORDERINGS: Dict[str, Callable] = {
    "random": order_random,
    "bfs": order_bfs,
    "degree": order_degree,
    "revdegree": order_revdegree,
    "strongruns": order_strongruns,
    "shingle": order_shingle,
    "hybrid": order_hybrid,
}


def order_nodes(csr: CSRGraph, method: str, seed: int = 0) -> np.ndarray:
    return ORDERINGS[method](csr, seed)


def apply_ordering(csr: CSRGraph, perm: np.ndarray) -> CSRGraph:
    """Relabel nodes: new_id = perm[old_id]; neighbor sets stay sorted."""
    src = np.repeat(np.arange(csr.n), csr.degrees)
    new_src = perm[src].astype(np.int64)
    new_dst = perm[csr.neighbors].astype(np.int64)
    return CSRGraph.from_edges(new_src, new_dst, n=csr.n,
                               annotation=csr.annotation)
