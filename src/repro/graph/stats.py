"""Graph/skew statistics (paper Table 3 + footnote 4).

Density skew is measured with Pearson's first coefficient of skewness,
3 * (mean - mode) / sigma, over the per-node neighbor-set densities.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.layouts import set_ranges
from repro.core.trie import CSRGraph


def density_skew(csr: CSRGraph) -> float:
    """Pearson's first coefficient over per-set density (|S| / range)."""
    deg = csr.degrees
    rng = set_ranges(csr)
    nz = deg > 0
    if nz.sum() < 2:
        return 0.0
    density = deg[nz] / np.maximum(rng[nz], 1)
    sigma = float(density.std())
    if sigma == 0:
        return 0.0
    hist, edges = np.histogram(density, bins=64)
    mode = float((edges[np.argmax(hist)] + edges[np.argmax(hist) + 1]) / 2)
    return float(3.0 * (density.mean() - mode) / sigma)


def graph_stats(csr: CSRGraph) -> Dict[str, float]:
    deg = csr.degrees
    return {
        "nodes": int(csr.n),
        "edges": int(csr.m),
        "max_degree": int(deg.max()) if csr.n else 0,
        "mean_degree": float(deg.mean()) if csr.n else 0.0,
        "density_skew": density_skew(csr),
    }
