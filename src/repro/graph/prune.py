"""Symmetric pruning (paper Section 5.2.1 / Appendix C.2.2).

For symmetric pattern queries on undirected graphs, each undirected edge is
kept once with src > dst (ids assigned by the node ordering), which makes
each triangle/clique counted exactly once and halves the data.
"""
from __future__ import annotations

import numpy as np

from repro.core.trie import CSRGraph


def prune_symmetric(csr: CSRGraph) -> CSRGraph:
    """Keep only edges with src > dst ("symmetrically filtered" data)."""
    src = np.repeat(np.arange(csr.n), csr.degrees)
    dst = csr.neighbors.astype(np.int64)
    keep = src > dst
    return CSRGraph.from_edges(src[keep], dst[keep], n=csr.n,
                               annotation=csr.annotation[keep]
                               if csr.annotation is not None else None)


def symmetrize(src, dst, n=None) -> CSRGraph:
    """Undirected view: add both directions, dedup, drop self-loops."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    return CSRGraph.from_edges(s, d, n=n)
