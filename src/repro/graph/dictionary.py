"""Dictionary encoding (paper Section 2.2).

Maps arbitrary input values to dense 32-bit unsigned integer ids. The order
of id assignment is the node ordering — see ``repro.graph.ordering`` for the
orderings the paper studies (degree/BFS/hybrid/...).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Dictionary:
    """A bijection value <-> int32 id."""

    to_id: Dict[object, int]
    to_value: list

    @property
    def size(self) -> int:
        return len(self.to_value)

    @staticmethod
    def build(values: Iterable) -> "Dictionary":
        to_id: Dict[object, int] = {}
        to_value: list = []
        for v in values:
            if v not in to_id:
                to_id[v] = len(to_value)
                to_value.append(v)
        return Dictionary(to_id, to_value)

    def encode(self, values) -> np.ndarray:
        return np.fromiter((self.to_id[v] for v in values), dtype=np.int32,
                           count=len(values))

    def decode(self, ids: np.ndarray) -> list:
        return [self.to_value[int(i)] for i in ids]

    def remap(self, perm: np.ndarray) -> "Dictionary":
        """Apply a node permutation: new_id = perm[old_id]."""
        inv = np.empty_like(perm)
        inv[perm] = np.arange(len(perm))
        to_value = [self.to_value[int(inv[i])] for i in range(len(perm))]
        return Dictionary({v: i for i, v in enumerate(to_value)}, to_value)


def encode_edges(src, dst,
                 dictionary: Optional[Dictionary] = None
                 ) -> Tuple[np.ndarray, np.ndarray, Dictionary]:
    """Encode raw edge endpoints to dense int32 ids (first-seen order)."""
    if dictionary is None:
        seen = []
        for v in list(src) + list(dst):
            seen.append(v)
        dictionary = Dictionary.build(seen)
    return dictionary.encode(src), dictionary.encode(dst), dictionary
