"""Train a reduced LM (any assigned --arch) on the synthetic token stream,
with checkpointing and auto-resume — the framework's training driver at
laptop scale. On a cluster, the identical step lowers under the production
mesh (see src/repro/launch/dryrun.py).

    PYTHONPATH=src python examples/train_lm.py --arch mixtral-8x7b \
        --steps 200 --ckpt-dir /tmp/ck_mixtral
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    if "--arch" not in sys.argv:
        sys.argv += ["--arch", "granite-3-8b"]
    if "--steps" not in sys.argv:
        sys.argv += ["--steps", "60"]
    train_main()
