"""Quickstart: the EmptyHeaded public API in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.engine import Engine

# a tiny undirected graph: two triangles sharing edge (1, 2)
edges = [(0, 1), (1, 2), (0, 2), (1, 3), (2, 3)]
src = np.array([u for u, v in edges] + [v for u, v in edges])
dst = np.array([v for u, v in edges] + [u for u, v in edges])

eng = Engine()
eng.load_edges("Edge", src, dst)
for alias in ("R", "S", "T"):
    eng.alias(alias, "Edge")

# 1. triangle listing (paper Table 2, row 1)
tri = eng.query("Triangle(x,y,z) :- R(x,y),S(y,z),T(x,z).")
print(f"triangle listing rows: {tri.num_rows} (expect 12 = 2 triangles x 6)")

# 2. counting with an aggregate
cnt = eng.query("CountTriangle(;w:long) :- R(x,y),S(y,z),T(x,z); "
                "w=<<COUNT(*)>>.")
print(f"triangle count: {int(cnt.scalar())}")

# 3. PageRank (recursive datalog; paper Table 2)
pr = eng.query(
    "N(;w:int) :- Edge(x,y); w=<<COUNT(x)>>.\n"
    "InvDeg(x;y:float) :- Edge(x,z); y=1.0/<<COUNT(z)>>.\n"
    "PageRank(x;y:float) :- Edge(x,z); y=1.0/N.\n"
    "PageRank(x;y:float)*[i=5] :- Edge(x,z),PageRank(z),InvDeg(z); "
    "y=0.15/N+0.85*<<SUM(z)>>.")
print("pagerank:", {k: round(v, 4) for k, v in pr.as_dict().items()})

# 4. SSSP (seminaive recursion, MIN semiring)
sssp = eng.query("SSSP(x;y:int) :- Edge(0,x); y=1.\n"
                 "SSSP(x;y:int)* :- Edge(w,x),SSSP(w); y=<<MIN(w)>>+1.")
print("sssp from node 0:", {k: int(v) for k, v in sssp.as_dict().items()})

# 5. inspect the logical plan (GHD)
print("\nGHD plan for the Barbell query:")
eng.alias("U", "Edge")
eng.alias("R2", "Edge")
eng.alias("S2", "Edge")
eng.alias("T2", "Edge")
print(eng.explain("B(;w:long) :- R(x,y),S(y,z),T(x,z),U(x,a),R2(a,b),"
                  "S2(b,c),T2(a,c); w=<<COUNT(*)>>."))

# 6. the device execution backend: trie levels live on device, and the
# hot-path intersections run through the layout-cohort Pallas kernels.
# Equivalent: REPRO_ENGINE_BACKEND=device python examples/quickstart.py
dev = Engine(backend="device")
dev.load_edges("Edge", src, dst)
for alias in ("R", "S", "T"):
    dev.alias(alias, "Edge")
cnt_dev = dev.query("CountTriangle(;w:long) :- R(x,y),S(y,z),T(x,z); "
                    "w=<<COUNT(*)>>.")
print(f"\ntriangle count on the device backend: {int(cnt_dev.scalar())} "
      f"(matches: {int(cnt_dev.scalar()) == int(cnt.scalar())})")
print("kernel-dispatch summary:")
for key, val in sorted(dev.dispatch_summary().items()):
    print(f"  {key:28s} {val}")
