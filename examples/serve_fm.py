"""Recsys serving: train a small FM for a few steps, then run the three
serving regimes of the assignment (p99 online scoring, offline bulk
scoring, 1-vs-1M retrieval), with the Pallas FM-interaction kernel.

    PYTHONPATH=src python examples/serve_fm.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.models.recsys.fm as fm
from repro.configs import get_arch
from repro.data import RecsysBatchGen
from repro.optim import adamw
from repro.serve.engine import batched_scores
from repro.train import TrainState, make_train_step


def main():
    cfg = dataclasses.replace(get_arch("fm").config, vocab_per_field=10_000)
    gen = RecsysBatchGen(cfg.n_sparse, cfg.vocab_per_field, batch=512)

    print("== train ==")
    params = fm.init(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-2)
    state = TrainState.create(params, opt).tree()
    step = jax.jit(make_train_step(lambda p, b: fm.loss_fn(p, b, cfg), opt))
    for i in range(30):
        b = jax.tree.map(jnp.asarray, gen.batch_at(i))
        state, m = step(state, b)
        if i % 10 == 0:
            print(f"  step {i:3d} bce {float(m['loss']):.4f}")
    params = state["params"]

    score = jax.jit(lambda b: fm.forward(params, b, cfg))

    print("\n== serve_p99 (online, batch 512) ==")
    b = {"ids": jnp.asarray(gen.batch_at(999)["ids"])}
    score(b).block_until_ready()
    lat = []
    for i in range(20):
        t0 = time.perf_counter()
        score(b).block_until_ready()
        lat.append((time.perf_counter() - t0) * 1e3)
    print(f"  p50 {np.percentile(lat, 50):.2f} ms   "
          f"p99 {np.percentile(lat, 99):.2f} ms")

    print("\n== serve_bulk (offline, 64k rows in 512-row chunks) ==")
    big = RecsysBatchGen(cfg.n_sparse, cfg.vocab_per_field, 65536)
    ids = big.batch_at(0)["ids"]
    t0 = time.perf_counter()
    out = batched_scores(lambda c: score({"ids": jnp.asarray(c["ids"])}),
                         {"ids": ids}, 4096)
    dt = time.perf_counter() - t0
    print(f"  {len(out)} rows in {dt:.2f}s = {len(out)/dt/1e3:.0f}k rows/s")

    print("\n== retrieval (1 user vs 1M candidates, batched dot) ==")
    cand = jnp.arange(1_000_000) % (cfg.total_rows)
    user = jnp.asarray([3, 50_007, 123_456])
    ret = jax.jit(lambda u, c: fm.retrieval_scores(params, u, c, cfg))
    ret(user, cand).block_until_ready()
    t0 = time.perf_counter()
    scores = ret(user, cand)
    top = jax.lax.top_k(scores, 5)
    jax.block_until_ready(top)
    dt = (time.perf_counter() - t0) * 1e3
    print(f"  scored 1M candidates in {dt:.1f} ms; "
          f"top-5 rows: {np.asarray(top[1]).tolist()}")


if __name__ == "__main__":
    main()
