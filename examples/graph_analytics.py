"""End-to-end driver (the paper's kind: a graph engine serving queries).

Builds a power-law graph, preprocesses it the EmptyHeaded way (dictionary
encoding -> degree ordering -> symmetric pruning -> set-level layout
optimization), then serves a batch of pattern + analytics queries and
reports per-query latency and the layout optimizer's decisions.

    PYTHONPATH=src python examples/graph_analytics.py [--nodes 5000]

Pass ``--backend device`` (or set ``REPRO_ENGINE_BACKEND=device``) to run
the whole query batch on the device-resident set store: trie levels are
uploaded once, each attribute extension is a single fused device call,
and the terminal-fold intersections dispatch to the layout-cohort Pallas
kernels. The kernel-dispatch summary printed at the end shows which
kernel handled each intersection.
"""
import argparse
import os
import time

import numpy as np

from repro.core.engine import Engine
from repro.core.layouts import HybridSetStore
from repro.data import powerlaw_graph
from repro.graph import (apply_ordering, graph_stats, order_nodes,
                         prune_symmetric)
from repro.kernels.bitset_intersect.ops import as_word_kernel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=3000)
    ap.add_argument("--mean-deg", type=float, default=12)
    ap.add_argument("--exponent", type=float, default=1.9)
    ap.add_argument("--backend",
                    default=os.environ.get("REPRO_ENGINE_BACKEND", "numpy"),
                    choices=("numpy", "device"),
                    help="execution backend for the query engine")
    args = ap.parse_args()
    print(f"== backend: {args.backend} ==")

    print("== build + preprocess ==")
    g = powerlaw_graph(args.nodes, args.mean_deg, args.exponent, seed=0)
    print("graph:", graph_stats(g))
    g = apply_ordering(g, order_nodes(g, "hybrid"))
    pruned = prune_symmetric(g)

    store = HybridSetStore.build(pruned,
                                 word_kernel=as_word_kernel(interpret=True))
    print("layout optimizer:", store.stats())

    print("\n== serve pattern queries (WCOJ engine) ==")
    eng = Engine(backend=args.backend)
    src = np.repeat(np.arange(g.n), g.degrees)
    eng.load_edges("Edge", src, g.neighbors)
    psrc = np.repeat(np.arange(pruned.n), pruned.degrees)
    eng_p = Engine(backend=args.backend)
    eng_p.load_edges("Edge", psrc, pruned.neighbors)
    for e in (eng, eng_p):
        for a in ("R", "S", "T", "U", "X", "Y", "R2", "S2", "T2"):
            e.alias(a, "Edge")

    queries = [
        ("triangle count (pruned)", eng_p,
         "C(;w:long) :- R(x,y),S(y,z),T(x,z); w=<<COUNT(*)>>."),
        ("4-clique count (pruned)", eng_p,
         "C(;w:long) :- R(x,y),S(y,z),T(x,z),U(x,a),X(y,a),Y(z,a); "
         "w=<<COUNT(*)>>."),
        ("lollipop count", eng,
         "C(;w:long) :- R(x,y),S(y,z),T(x,z),U(x,a); w=<<COUNT(*)>>."),
        ("barbell count (GHD early-agg)", eng,
         "C(;w:long) :- R(x,y),S(y,z),T(x,z),U(x,a),R2(a,b),S2(b,c),"
         "T2(a,c); w=<<COUNT(*)>>."),
        ("pagerank 5 iters", eng,
         "N(;w:int) :- Edge(x,y); w=<<COUNT(x)>>.\n"
         "InvDeg(x;y:float) :- Edge(x,z); y=1.0/<<COUNT(z)>>.\n"
         "PageRank(x;y:float) :- Edge(x,z); y=1.0/N.\n"
         "PageRank(x;y:float)*[i=5] :- Edge(x,z),PageRank(z),InvDeg(z); "
         "y=0.15/N+0.85*<<SUM(z)>>."),
        ("sssp from hub", eng,
         f"SSSP(x;y:int) :- Edge({int(np.argmax(g.degrees))},x); y=1.\n"
         "SSSP(x;y:int)* :- Edge(w,x),SSSP(w); y=<<MIN(w)>>+1."),
    ]
    for name, engine, q in queries:
        t0 = time.perf_counter()
        res = engine.query(q)
        dt = (time.perf_counter() - t0) * 1e3
        val = (int(res.scalar()) if not res.vars else f"{res.num_rows} rows")
        print(f"  {name:34s} {dt:8.1f} ms   -> {val}")

    print("\n== kernel-dispatch summary (which kernel handled each "
          "intersection) ==")
    merged = dict(eng.dispatch_summary())
    for k, v in eng_p.dispatch_summary().items():
        merged[k] = merged.get(k, 0) + v
    for key in sorted(merged):
        print(f"  {key:28s} {merged[key]}")

    print("\n== MXU dense-cohort triangle count (beyond-paper path) ==")
    from repro.kernels.triangle_mm.ops import densify_csr, triangle_count_dense
    t0 = time.perf_counter()
    dense = densify_csr(pruned.offsets, pruned.neighbors, pruned.n)
    c = int(triangle_count_dense(dense, symmetric=False))
    print(f"  triangle_mm: {c} in {(time.perf_counter()-t0)*1e3:.1f} ms")


if __name__ == "__main__":
    main()
